"""Flight recorder (repro.obs.flight) + trajectory gate (repro.obs
.regress): the zero-sync contract (recorder off OR on adds ZERO fences
and leaves results byte-identical), the telescoping latency breakdown,
randomized conflict-witness soundness, async-lane Chrome-trace
invariants, streaming quantile accuracy, and the EWMA regression gate's
direction handling."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BohmEngine
from repro.core.plan import (batch_footprint, conflict_witness,
                             footprints_conflict)
from repro.core.txn import Workload, make_batch
from repro.obs import (NULL_FLIGHT, FlightRecorder, LogHistogram,
                       PhaseTracer, append_entry, check_history,
                       direction_for, history_path, load_history,
                       stitch_chrome_trace, validate_chrome_trace)
from repro.service import TxnService

T, OPS, R = 16, 3, 64


def _inc_workload():
    def rmw(vals, args):
        return vals.at[..., 0].add(args[0]), jnp.zeros((), bool)

    def read_only(vals, args):
        return vals, jnp.zeros((), bool)

    return Workload(name="inc", n_read=OPS, n_write=OPS, payload_words=2,
                    branches=(rmw, read_only))


def _random_batch(seed: int, lo: int = 0, hi: int = R, t: int = T):
    rng = np.random.default_rng(seed)
    reads = rng.integers(lo, hi, (t, OPS))
    wmask = rng.random((t, OPS)) < 0.6
    writes = np.where(wmask, reads, -1)
    types = rng.integers(0, 2, t)
    args = rng.integers(1, 5, (t, 1))
    return make_batch(reads, writes, types, args)


def _run_stream(flight, n=6, **svc_kw):
    """One conflict-aware OOO stream; returns (service, read values)."""
    eng = BohmEngine(R, _inc_workload(), ring_slots=8)
    svc = TxnService(eng, max_inflight=2, admission_window=4,
                     max_inflight_execs=2, flight=flight, **svc_kw)
    tickets = svc.submit_many([_random_batch(s) for s in range(n)])
    tickets.append(svc.submit(_random_batch(99, hi=8, t=4),
                              latency_class="interactive"))
    reads = [np.asarray(svc.wait(t).read_vals) for t in tickets]
    svc.drain()
    return svc, reads


# ------------------------------------------------------- zero-sync contract
def test_flight_adds_zero_fences_and_results_identical(monkeypatch):
    """The recorder — OFF or ON — introduces no jax fences (stamps ride
    joins the scheduler already performs) and leaves every read result
    byte-identical."""
    _, want = _run_stream(None)                       # no recorder at all

    calls = {"n": 0}
    real = jax.block_until_ready

    def counting(x):
        calls["n"] += 1
        return real(x)

    fences = {}
    for name, flight in [("off", FlightRecorder(enabled=False)),
                         ("on", FlightRecorder(enabled=True))]:
        calls["n"] = 0
        monkeypatch.setattr(jax, "block_until_ready", counting)
        svc, got = _run_stream(flight)
        monkeypatch.setattr(jax, "block_until_ready", real)
        fences[name] = calls["n"]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
        assert svc.flight is flight
    # identical fence count whether the recorder is off or on: the
    # whole point of host-side stamping at existing transitions
    assert fences["on"] == fences["off"]


def test_null_flight_records_nothing():
    svc, _ = _run_stream(FlightRecorder(enabled=False))
    assert not svc.flight.records() and not svc.flight.inflight()
    assert svc.flight.completed == 0
    assert NULL_FLIGHT.records() == []    # the shared default, untouched


# -------------------------------------------------- breakdown + SLO gauges
def test_breakdown_telescopes_and_health_slo():
    flight = FlightRecorder(enabled=True)
    svc, _ = _run_stream(flight)
    recs = flight.records()
    assert len(recs) == 7 and flight.completed == 7
    for f in recs:
        assert f.complete
        bd = f.breakdown()
        parts = sum(bd[p] for p in
                    ("queue", "formation", "exec", "commit_defer"))
        assert parts == pytest.approx(bd["total"], abs=1e-9)
        assert all(v >= 0 for v in bd.values())
        assert bd["total"] == f.t_visible - f.t_submit

    health = svc.health()
    slo = health["flight_slo"]
    assert set(slo) == {"interactive", "bulk"}
    assert slo["interactive"]["count"] == 1
    assert slo["bulk"]["count"] == 6
    for g in slo.values():
        assert 0 < g["p50_ms"] <= g["p99_ms"]
    assert health["flight_completed"] == 7
    assert health["flight_inflight"] == 0


def test_conflict_attribution_populates_heatmap():
    """A deliberately conflict-heavy stream (every batch hits the same
    8-record range) must produce blocked events with real witnesses."""
    flight = FlightRecorder(enabled=True)
    eng = BohmEngine(R, _inc_workload(), ring_slots=8)
    svc = TxnService(eng, max_inflight=2, admission_window=4,
                     max_inflight_execs=2, flight=flight)
    for t in svc.submit_many([_random_batch(s, hi=8) for s in range(6)]):
        svc.wait(t)
    svc.drain()
    assert flight.block_kinds.get("epoch-conflict", 0) > 0
    top = flight.blocking_top()
    assert top and all(n >= 1 for _, n in top)
    assert [n for _, n in top] == sorted(
        (n for _, n in top), reverse=True)
    # every heatmap record is a real record id in range
    assert all(0 <= rec < R for rec, _ in top)


# ------------------------------------------------- conflict witness (prop)
def test_conflict_witness_randomized_soundness():
    """witness(a, b) is a record written by one side and touched by the
    other; None exactly when the footprints commute."""
    rng = np.random.default_rng(7)
    n_r = 320
    fps = []
    for _ in range(24):
        t = int(rng.integers(1, 6))
        reads = rng.integers(0, n_r, (t, 4))
        writes = np.where(rng.random((t, 4)) < 0.5, reads, -1)
        batch = make_batch(reads, writes, np.zeros(t), np.zeros((t, 1)))
        fps.append(batch_footprint(batch, n_r))

    def touched(fp, rec):
        return bool(int(fp.rw_bits[rec >> 6]) >> (rec & 63) & 1)

    def written(fp, rec):
        return bool(int(fp.write_bits[rec >> 6]) >> (rec & 63) & 1)

    checked_conflicts = 0
    for i, a in enumerate(fps):
        for b in fps[i + 1:]:
            w = conflict_witness(a, b)
            if footprints_conflict(a, b):
                assert w is not None
                assert ((written(a, w) and touched(b, w))
                        or (written(b, w) and touched(a, w)))
                checked_conflicts += 1
            else:
                assert w is None
    assert checked_conflicts > 10    # the workload actually conflicts


# ----------------------------------------------------- async-lane export
def test_async_lanes_validate_and_stitch():
    flight = FlightRecorder(enabled=True)
    flight.on_submit(0, 0, 16)
    flight.on_submit(1, 1, 16)
    flight.on_dispatch([0, 1], epoch=0, epoch_txns=32, epoch_batches=2)
    flight.on_blocked(1, "epoch-conflict", blocker=0, witness=42)
    flight.on_exec([0, 1], chain_depth=2)
    flight.on_commit([0, 1])
    flight.on_visible(0)
    flight.on_visible(1)

    events = flight.to_async_events(t0=flight.earliest_ts())
    counts = validate_chrome_trace({"traceEvents": events})
    assert counts["async_lanes"] == 2
    # ticket span + 4 phase spans per lane
    assert counts["async_spans"] == 2 * 5
    blocked = [e for e in events if e["name"] == "blocked"]
    assert len(blocked) == 1 and blocked[0]["args"]["witness"] == 42

    tracer = PhaseTracer(enabled=True)
    with tracer.span("plan", txns=32):
        pass
    trace = stitch_chrome_trace(tracer, flight)
    counts = validate_chrome_trace(trace)
    assert counts["async_lanes"] == 2 and counts["spans"] == 1
    assert trace["otherData"]["flight_tickets"] == 2
    assert json.loads(json.dumps(trace)) == trace    # JSON-serializable


def test_async_lane_validator_rejects_malformed():
    ok = {"name": "ticket", "ph": "b", "ts": 0, "pid": 0, "tid": 0,
          "cat": "flight", "id": "7"}
    # 'e' without an open 'b' in the same lane
    bad_e = dict(ok, ph="e", id="8")
    with pytest.raises(ValueError, match="without open"):
        validate_chrome_trace({"traceEvents": [ok, bad_e]})
    # async event without an id
    no_id = {k: v for k, v in ok.items() if k != "id"}
    with pytest.raises(ValueError, match="missing 'id'"):
        validate_chrome_trace({"traceEvents": [no_id]})
    # dangling 'b' (lane never closed)
    with pytest.raises(ValueError, match="never closed"):
        validate_chrome_trace({"traceEvents": [ok]})


def test_flight_capacity_bounded():
    flight = FlightRecorder(capacity=4, enabled=True)
    for tk in range(10):
        flight.on_submit(tk, 1, 1)
        flight.on_dispatch([tk], epoch=tk, epoch_txns=1, epoch_batches=1)
        flight.on_exec([tk])
        flight.on_commit([tk])
        flight.on_visible(tk)
    assert len(flight.records()) == 4
    assert flight.dropped == 6
    assert flight.completed == 10          # counters keep the true total
    assert [f.ticket for f in flight.records()] == [6, 7, 8, 9]


# ------------------------------------------------------ quantile digests
def test_log_histogram_tracks_numpy_percentiles():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(mean=-7.0, sigma=1.2, size=4000)   # latency-like
    h = LogHistogram()
    h.extend(xs)
    assert h.count == 4000
    for q in (50.0, 90.0, 99.0):
        got, want = h.quantile(q), float(np.percentile(xs, q))
        assert got == pytest.approx(want, rel=2 * h.rel_error)
    assert h.quantile(0.0) == pytest.approx(xs.min())
    assert h.quantile(100.0) == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean(), rel=1e-9)

    h2 = LogHistogram()
    h2.extend(xs[:1000])
    h3 = LogHistogram()
    h3.extend(xs[1000:])
    h2.merge(h3)
    assert h2.quantile(99.0) == pytest.approx(h.quantile(99.0))
    # round-trips through its dict form
    back = LogHistogram.from_dict(h.to_dict())
    assert back.quantile(50.0) == h.quantile(50.0)


# ----------------------------------------------------- trajectory gate
def test_regress_directions():
    assert direction_for("txn_s") == "higher"
    assert direction_for("vs_barriered") == "higher"
    assert direction_for("p99_ms") == "lower"
    assert direction_for("us_per_txn") == "lower"
    assert direction_for("found_rate") == "higher"


def test_regress_gate_flags_newest_entry(tmp_path):
    path = history_path("demo", str(tmp_path))
    for _ in range(5):
        append_entry(path, "demo",
                     {"txn_s": 1000.0, "p99_ms": 4.0}, meta={"git": "x"})
    assert check_history(load_history(path)) == []    # steady: no flags

    # throughput collapse (higher-better) + latency blowup (lower-better)
    append_entry(path, "demo", {"txn_s": 200.0, "p99_ms": 40.0},
                 meta={"git": "y"})
    regs = check_history(load_history(path))
    assert {r.metric for r in regs} == {"txn_s", "p99_ms"}
    for r in regs:
        assert r.ratio > 1.5 and r.suite == "demo"
        assert "demo/" in r.describe()

    # an IMPROVEMENT must not be flagged
    path2 = history_path("demo2", str(tmp_path))
    for v in (1000.0, 1000.0, 1000.0, 5000.0):
        append_entry(path2, "demo2", {"txn_s": v}, meta={})
    assert check_history(load_history(path2)) == []


def test_regress_history_bounded_and_stamped(tmp_path):
    path = history_path("cap", str(tmp_path))
    for i in range(8):
        append_entry(path, "cap", {"m_us": float(i)}, max_entries=5)
    hist = load_history(path)
    assert len(hist["entries"]) == 5
    assert [e["metrics"]["m_us"] for e in hist["entries"]] == \
        [3.0, 4.0, 5.0, 6.0, 7.0]
    # default meta is the provenance stamp
    assert "jax_version" in hist["entries"][-1]["meta"]
    assert "git_sha" in hist["entries"][-1]["meta"]
