"""Sharding rules: divisibility fallbacks, param/spec tree congruence, and
the jaxpr cost counter's calibration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.models import abstract_params
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_drops_nondivisible(mesh):
    rules = {"a": "model", "b": ("pod", "data")}
    # 'model' size 1 divides everything -> kept
    assert shd.spec_for((7, 4), ("a", "b"), rules, mesh) == P(None, "data") \
        or shd.spec_for((7, 4), ("a", "b"), rules, mesh) == P("model",
                                                              ("data",))


def test_param_sharding_tree_matches(mesh):
    for arch in ("smollm-360m", "deepseek-v2-lite-16b", "hymba-1.5b",
                 "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        params = abstract_params(cfg)
        sh = shd.param_shardings(cfg, mesh)
        # identical tree structure
        jax.tree.map(lambda a, b: None, params, sh)


def test_head_divisibility_rules():
    """q/kv head sharding only when head count divides TP degree."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    cfg = get_config("smollm-360m")       # 15 heads, kv 5
    rules = shd.logical_rules(cfg, FakeMesh())
    assert rules["q_proj"] is None and rules["kv_proj"] is None
    cfg = get_config("qwen3-32b")         # 64 heads, kv 8
    rules = shd.logical_rules(cfg, FakeMesh())
    assert rules["q_proj"] == "model" and rules["kv_proj"] is None
    cfg = get_config("deepseek-v2-lite-16b")   # 64 experts -> EP
    rules = shd.logical_rules(cfg, FakeMesh())
    assert rules["experts"] == "model"
    cfg = get_config("grok-1-314b")            # 8 experts -> internal TP
    rules = shd.logical_rules(cfg, FakeMesh())
    assert rules["experts"] is None and rules["expert_mlp"] == "model"


def test_jaxpr_counter_calibration():
    from repro.launch.counting import jaxpr_costs
    L, B, D = 4, 32, 64

    def f(x, ws):
        def body(h, w):
            return h @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = jaxpr_costs(f, x, ws)
    expect = 2 * L * B * D * D
    assert abs(c["dot_flops"] - expect) / expect < 0.01
    g = jaxpr_costs(jax.grad(f, argnums=1), x, ws)
    assert abs(g["dot_flops"] - 3 * expect) / (3 * expect) < 0.01


def test_batch_sharding_nondivisible(mesh):
    s = shd.batch_sharding(mesh, (3, 5))
    assert s.spec == P(("data",), None) or s.spec == P()
