"""Out-of-order admission (repro.service): reordered schedules must be
provably serial-equivalent, and the proof obligations are byte-level:

  * per-ticket read values and the head store equal the SUBMISSION-order
    sequential schedule (hops only ever swap commuting batches);
  * ring state — begin/end timestamps, payloads, heads, ``base_ts``,
    ``ts_counter`` — equals sequential ``run_batch`` calls in DISPATCH
    order (``service.dispatch_log``), after one ``gc_sweep`` per side
    canonicalises merged epochs' deferred eviction, because the plan
    layer re-derives global timestamps from the dispatch order;
  * a snapshot pinned MID-window reads identically in both schedules;
  * a perpetually conflicting batch is dispatched within ``max_hops``
    formations (starvation bound), and an interactive batch jumps queued
    bulk work it commutes with (latency classes).

The hypothesis half fuzzes stream shapes and scheduler knobs when the
package is installed (CI); the seeded sweep always runs, so the
container suite exercises the same invariants without the extra
dependency.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BohmEngine
from repro.core.txn import Workload, make_batch
from repro.service import TxnService

R = 128
T, OPS = 8, 2
N_STRIPES = 8


def _wl():
    def rmw(vals, args):
        return vals.at[..., 0].add(args[0]), jnp.zeros((), bool)

    def ro(vals, args):
        return vals, jnp.zeros((), bool)

    return Workload(name="inc", n_read=OPS, n_write=OPS, payload_words=2,
                    branches=(rmw, ro))


def _stripe_batch(rng, stripe):
    """RMW batch confined to one of N_STRIPES disjoint key ranges —
    batches of different stripes commute, same-stripe batches conflict."""
    lo = stripe * (R // N_STRIPES)
    reads = rng.integers(lo, lo + R // N_STRIPES, (T, OPS))
    writes = np.where(rng.random((T, OPS)) < 0.8, reads, -1)
    return make_batch(reads, writes, rng.integers(0, 2, T),
                      rng.integers(1, 5, (T, 1)))


def _run_sequential(batches, order, pin_after_epochs=None,
                    dispatch_log=None):
    """Sequential run_batch oracle in the given batch order; with
    ``dispatch_log`` the pin lands at the same epoch boundary the
    service pinned at."""
    eng = BohmEngine(R, _wl(), ring_slots=8)
    reads, snap = {}, None
    done = 0
    if pin_after_epochs == 0:
        snap = eng.begin_snapshot()
    for i in order:
        r, _ = eng.run_batch(batches[i])
        reads[i] = np.asarray(r)
        done += 1
        if (dispatch_log is not None and pin_after_epochs is not None
                and snap is None):
            covered = sum(len(ep) for ep in
                          dispatch_log[:pin_after_epochs])
            if done == covered:
                snap = eng.begin_snapshot()
    return eng, reads, snap


def _check_equivalence(batches, classes, pin_at, **svc_kw):
    """The full obligation set for one stream."""
    eng1 = BohmEngine(R, _wl(), ring_slots=8)
    svc = TxnService(eng1, **svc_kw)
    tickets, snap1, pin_epochs = [], None, None
    for i, b in enumerate(batches):
        tickets.append(svc.submit(b, latency_class=classes[i]))
        if i == pin_at:
            snap1 = svc.begin_snapshot()
            pin_epochs = len(svc.dispatch_log)
    reads1 = {i: np.asarray(svc.wait(t).read_vals)
              for i, t in enumerate(tickets)}
    svc.drain()

    flat = [t for ep in svc.dispatch_log for t in ep]
    assert sorted(flat) == list(range(len(batches)))

    # (a) submission-order oracle: per-ticket reads + head store
    eng0, reads0, _ = _run_sequential(batches, range(len(batches)))
    for i in reads0:
        np.testing.assert_array_equal(reads0[i], reads1[i])
    np.testing.assert_array_equal(np.asarray(eng0.snapshot()),
                                  np.asarray(eng1.snapshot()))

    # (b) dispatch-order oracle: full store byte-identity, pinned
    # snapshot included
    engd, readsd, snapd = _run_sequential(
        batches, flat, pin_after_epochs=pin_epochs,
        dispatch_log=svc.dispatch_log)
    for i in readsd:
        np.testing.assert_array_equal(readsd[i], reads1[i])
    assert int(eng1.store.ts_counter) == int(engd.store.ts_counter)
    if snap1 is not None:
        assert snapd is not None and snapd.ts == snap1.ts
        v0, f0 = engd.snapshot_read(np.arange(R), snapd)
        v1, f1 = eng1.snapshot_read(np.arange(R), snap1)
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    engd.gc_sweep()
    eng1.gc_sweep()
    np.testing.assert_array_equal(np.asarray(engd.snapshot()),
                                  np.asarray(eng1.snapshot()))
    np.testing.assert_array_equal(np.asarray(engd.store.base_ts),
                                  np.asarray(eng1.store.base_ts))
    for f in ("begin", "end", "payload", "head"):
        np.testing.assert_array_equal(
            np.asarray(getattr(engd.store.versions.rings, f)),
            np.asarray(getattr(eng1.store.versions.rings, f)), f)
    return svc


def _gen_stream(rng, n):
    """Hop-provoking shape: same-stripe bursts (head-of-line blockers)
    interleaved with fresh-stripe traffic and occasional interactive
    point batches."""
    batches, classes = [], []
    stripe = 0
    for i in range(n):
        roll = rng.random()
        if roll < 0.35:
            s = 0                     # the contended stripe
        else:
            stripe = (stripe + 1) % N_STRIPES
            s = stripe
        batches.append(_stripe_batch(rng, s))
        classes.append("interactive" if rng.random() < 0.2 else "bulk")
    return batches, classes


# ---------------------------------------------------------------------------
# seeded sweep (always runs)
# ---------------------------------------------------------------------------
def test_reordered_schedule_byte_identical_seeded():
    hopped = 0
    for seed, kw in [
        (3, dict(max_inflight=4, admission_window=8,
                 max_inflight_execs=4)),
        (11, dict(max_inflight=3, admission_window=6,
                  max_inflight_execs=3, max_hops=2)),
        (23, dict(max_inflight=2, admission_window=4,
                  max_inflight_execs=2, max_hops=1)),
    ]:
        rng = np.random.default_rng(seed)
        batches, classes = _gen_stream(rng, 10)
        svc = _check_equivalence(batches, classes, pin_at=4, **kw)
        hopped += svc.stats["hopped_batches"]
    # the sweep must actually exercise reordering, not vacuously pass
    assert hopped > 0


def test_starvation_bound():
    """After max_hops jumps a conflicting batch saturates into a
    barrier: later-submitted commuting work stops jumping it and drains
    behind it, while a loose budget keeps hopping.  Either way every
    blocker is dispatched within a bounded number of formations."""
    rng = np.random.default_rng(5)
    # four same-stripe blockers (pairwise conflicting), then cold work
    stream = [_stripe_batch(rng, 0) for _ in range(4)] + \
        [_stripe_batch(rng, 1 + (k % (N_STRIPES - 1))) for k in range(10)]

    def run(max_hops):
        eng = BohmEngine(R, _wl(), ring_slots=8)
        svc = TxnService(eng, max_inflight=4, admission_window=6,
                         max_inflight_execs=4, max_hops=max_hops)
        tickets = svc.submit_many(stream)
        for t in tickets:
            svc.wait(t)
        svc.drain()
        return svc

    def epoch_of(svc, t):
        return next(i for i, ep in enumerate(svc.dispatch_log)
                    if t in ep)

    svc_tight = run(max_hops=1)
    svc_loose = run(max_hops=8)
    assert svc_loose.stats["hopped_batches"] > 0
    # starvation bound: blocker i conflicts with the i earlier
    # same-stripe batches, so under ANY budget it seeds an epoch no
    # later than one formation per predecessor
    for svc in (svc_tight, svc_loose):
        for i in range(4):
            assert epoch_of(svc, i) <= i + 1
    # the bound binds on the COLD work: with a loose budget cold batch
    # 6 hops the queued blockers repeatedly and dispatches before the
    # last blocker; at max_hops=1 the blockers saturate after one jump
    # and become barriers the cold work drains behind
    assert epoch_of(svc_loose, 6) < epoch_of(svc_loose, 3)
    assert epoch_of(svc_tight, 6) >= epoch_of(svc_tight, 3)
    assert (svc_loose.stats["hopped_batches"]
            > svc_tight.stats["hopped_batches"])


def test_interactive_jumps_bulk():
    """An interactive point batch submitted behind conflicting bulk work
    it commutes with is dispatched ahead of queued bulk batches, and the
    promotion is counted."""
    rng = np.random.default_rng(9)
    eng = BohmEngine(R, _wl(), ring_slots=8)
    svc = TxnService(eng, max_inflight=4, admission_window=8,
                     max_inflight_execs=4)
    t_bulk = [svc.submit(_stripe_batch(rng, 0)) for _ in range(3)]
    t_int = svc.submit(_stripe_batch(rng, 1),
                       latency_class="interactive")
    for t in t_bulk + [t_int]:
        svc.wait(t)
    svc.drain()
    assert svc.stats["class_promotions"] >= 1
    flat = [t for ep in svc.dispatch_log for t in ep]
    # the interactive ticket lands before at least one earlier bulk one
    assert flat.index(t_int) < max(flat.index(t) for t in t_bulk)


def test_fifo_mode_never_hops():
    """reorder=False restores the PR-3 FIFO-prefix merge exactly."""
    rng = np.random.default_rng(13)
    batches, classes = _gen_stream(rng, 8)
    eng = BohmEngine(R, _wl(), ring_slots=8)
    svc = TxnService(eng, max_inflight=2, admission_window=4,
                     reorder=False)
    tickets = [svc.submit(b) for b in batches]
    for t in tickets:
        svc.wait(t)
    svc.drain()
    assert svc.stats["hopped_batches"] == 0
    flat = [t for ep in svc.dispatch_log for t in ep]
    assert flat == sorted(flat)


# ---------------------------------------------------------------------------
# hypothesis fuzz (CI)
# ---------------------------------------------------------------------------
def test_reordered_schedule_byte_identical_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16),
           n=st.integers(4, 12),
           window=st.integers(2, 8),
           max_inflight=st.integers(1, 4),
           max_execs=st.integers(1, 4),
           max_hops=st.integers(1, 6),
           pin_at=st.integers(0, 3))
    def run(seed, n, window, max_inflight, max_execs, max_hops, pin_at):
        rng = np.random.default_rng(seed)
        batches, classes = _gen_stream(rng, n)
        _check_equivalence(batches, classes, pin_at=min(pin_at, n - 1),
                           max_inflight=max_inflight,
                           admission_window=window,
                           max_inflight_execs=max_execs,
                           max_hops=max_hops)

    run()
